"""NoC layer edge cases under real multi-device shard_map (subprocess with
forced host devices): mesh_transpose on non-square meshes, gather/scatter of
batch-stacked shards, reverse_vector / pull_shard semantics, and the
1D-plan fallback with batched vectors.  Single-tile-axis identities (p == 1
must emit NO ppermute) run in-process on a (1, 1) mesh."""

import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import noc
from repro.core.engine import _shard_map
from repro.launch.mesh import make_mesh


def test_single_tile_axes_are_identity_without_ppermute():
    """p == 1 along every axis: neighbor_shift / pull_shard /
    mesh_transpose / reverse_vector must be value-identities AND emit no
    collective-permute at all (the NoC hop disappears, not a no-op
    message).  Runs on the ordinary single-device test process."""
    mesh = make_mesh((1, 1), ("data", "model"))
    x = jnp.arange(12, dtype=jnp.float64)
    spec = P(("data", "model"))

    cases = {
        "neighbor_shift": lambda s: noc.neighbor_shift(s, "data", 1),
        "pull_shard": lambda s: noc.pull_shard(s, ("data", "model"), 1),
        "mesh_transpose": lambda s: noc.mesh_transpose(s, ("data",), ("model",)),
    }
    for name, fn in cases.items():
        f = jax.jit(_shard_map(fn, mesh, in_specs=spec, out_specs=spec))
        assert np.array_equal(np.asarray(f(x)), np.asarray(x)), name
        hlo = f.lower(x).as_text()
        assert "collective-permute" not in hlo and "ppermute" not in hlo, name

    # reverse_vector on one tile is the pure local flip -- still no hop
    f = jax.jit(_shard_map(lambda s: noc.reverse_vector(s, ("data", "model")),
                           mesh, in_specs=spec, out_specs=spec))
    assert np.array_equal(np.asarray(f(x)), np.asarray(x)[::-1])
    hlo = f.lower(x).as_text()
    assert "collective-permute" not in hlo and "ppermute" not in hlo

    # batched shards flip the vector axis, never the batch axis
    xb = jnp.stack([x, 2.0 * x])
    fb = jax.jit(_shard_map(
        lambda s: noc.reverse_vector(s, ("data", "model"), vec_axis=1),
        mesh, in_specs=P(None, ("data", "model")),
        out_specs=P(None, ("data", "model"))))
    assert np.array_equal(np.asarray(fb(xb)), np.asarray(xb)[:, ::-1])


def test_zero_shift_elided():
    mesh = make_mesh((1, 1), ("data", "model"))
    x = jnp.arange(8, dtype=jnp.float64)
    f = jax.jit(_shard_map(lambda s: noc.neighbor_shift(s, "data", 0),
                           mesh, in_specs=P(("data", "model")),
                           out_specs=P(("data", "model"))))
    assert np.array_equal(np.asarray(f(x)), np.asarray(x))
    assert "collective-permute" not in f.lower(x).as_text()

_SCRIPT = r"""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
import scipy.sparse as sp
from repro.core import noc
from repro.core.engine import AzulEngine, _shard_map
from repro.core.formats import csr_from_scipy
from repro.launch.mesh import make_mesh

# --- mesh_transpose semantics on non-square meshes, incl. batched shards ---
# L_row: segment q = i*pc + j lives on tile (i, j).  After the transpose,
# tile (i, j) must hold segment q' = j*pr + i (the L_col layout).
for (pr, pc) in ((2, 4), (4, 2), (2, 2)):
    mesh = make_mesh((pr, pc), ("data", "model"))
    u = 3
    npad = pr * pc * u
    x = np.arange(npad, dtype=np.float64)
    xb = np.stack([x, -x, x * 2.0])            # (k, npad) batch-stacked

    f = _shard_map(
        lambda s: noc.mesh_transpose(s, ("data",), ("model",)),
        mesh, in_specs=P(("data", "model")), out_specs=P(("data", "model")),
    )
    got = np.asarray(jax.jit(f)(jnp.asarray(x)))
    want = np.concatenate([
        x[((t % pc) * pr + t // pc) * u:((t % pc) * pr + t // pc + 1) * u]
        for t in range(pr * pc)
    ])
    assert np.array_equal(got, want), f"mesh_transpose {pr}x{pc}"

    fb = _shard_map(
        lambda s: noc.mesh_transpose(s, ("data",), ("model",)),
        mesh, in_specs=P(None, ("data", "model")),
        out_specs=P(None, ("data", "model")),
    )
    gotb = np.asarray(jax.jit(fb)(jnp.asarray(xb)))
    assert np.array_equal(gotb, np.stack([want, -want, want * 2.0])), \
        f"batched mesh_transpose {pr}x{pc}"

    # gather_along a batched shard reassembles the full vector on every tile
    fg = _shard_map(
        lambda s: noc.gather_along(s, ("data", "model"), vec_axis=1),
        mesh, in_specs=P(None, ("data", "model")), out_specs=P(),
    )
    gg = np.asarray(jax.jit(fg)(jnp.asarray(xb)))
    assert np.array_equal(gg, xb), f"batched gather {pr}x{pc}"

    # reduce_scatter of batched partials: P tiles each contribute the full
    # (k, npad) array -> every tile keeps its own (k, u) shard of P * x
    fs = _shard_map(
        lambda s: noc.reduce_scatter_along(
            noc.gather_along(s, ("data", "model"), vec_axis=1),
            ("data", "model"), vec_axis=1),
        mesh, in_specs=P(None, ("data", "model")),
        out_specs=P(None, ("data", "model")),
    )
    gs = np.asarray(jax.jit(fs)(jnp.asarray(xb)))
    assert np.allclose(gs, pr * pc * xb), f"batched reduce_scatter {pr}x{pc}"

    # reverse_vector: global reversal of contiguous shards, single + batched
    frv = _shard_map(
        lambda s: noc.reverse_vector(s, ("data", "model")),
        mesh, in_specs=P(("data", "model")), out_specs=P(("data", "model")),
    )
    grv = np.asarray(jax.jit(frv)(jnp.asarray(x)))
    assert np.array_equal(grv, x[::-1]), f"reverse_vector {pr}x{pc}"
    frvb = _shard_map(
        lambda s: noc.reverse_vector(s, ("data", "model"), vec_axis=1),
        mesh, in_specs=P(None, ("data", "model")),
        out_specs=P(None, ("data", "model")),
    )
    grvb = np.asarray(jax.jit(frvb)(jnp.asarray(xb)))
    assert np.array_equal(grvb, xb[:, ::-1]), f"batched reverse_vector {pr}x{pc}"

    # pull_shard: tile t receives shard (t + d) % P, for every delta
    Pn = pr * pc
    for d in (1, 2, Pn - 1, Pn):                     # Pn: identity wrap
        fp = _shard_map(
            lambda s, d=d: noc.pull_shard(s, ("data", "model"), d),
            mesh, in_specs=P(("data", "model")), out_specs=P(("data", "model")),
        )
        gp = np.asarray(jax.jit(fp)(jnp.asarray(x)))
        want_p = np.concatenate([
            x[((t + d) % Pn) * u:(((t + d) % Pn) + 1) * u] for t in range(Pn)
        ])
        assert np.array_equal(gp, want_p), f"pull_shard d={d} {pr}x{pc}"

# --- non-square 2d engines + 1D-plan fallback, batched end to end ----------
rng = np.random.default_rng(0)
n = 72
Bm = sp.random(n, n, density=0.08, random_state=1, format="csr")
A = (Bm @ Bm.T + sp.eye(n) * (n * 0.2)).tocsr()
m = csr_from_scipy(A)
Xt = rng.standard_normal((3, n))
Bk = Xt @ A.toarray().T

for shape in ((2, 4), (4, 2)):
    mesh = make_mesh(shape, ("data", "model"))
    eng = AzulEngine(m, mesh=mesh, mode="2d", precond="jacobi", dtype=np.float64)
    assert (eng.pr, eng.pc) == shape
    assert np.allclose(eng.spmv(Xt), Bk, atol=1e-8), f"{shape} 2d batched spmm"
    xk, _ = eng.solve(Bk, method="pcg", iters=80)
    assert np.allclose(xk, Xt, atol=1e-6), f"{shape} 2d batched solve"

# 1D fallback: nnz-balanced row partition, full-x gather per tile
mesh = make_mesh((2, 4), ("data", "model"))
eng1 = AzulEngine(m, mesh=mesh, mode="1d", precond="jacobi", dtype=np.float64)
assert np.allclose(eng1.spmv(Xt), Bk, atol=1e-8), "1d batched spmm"
x1, n1 = eng1.solve(Bk, method="pcg", iters=80)
assert x1.shape == (3, n) and n1.shape == (81, 3)
assert np.allclose(x1, Xt, atol=1e-6), "1d batched solve"

print("NOC_DIST_OK")
"""


@pytest.mark.slow
@pytest.mark.dist
def test_noc_edge_cases_multidevice():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = "src"
    env["JAX_ENABLE_X64"] = "1"
    r = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(__file__)), timeout=560,
    )
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr[-3000:]}"
    assert "NOC_DIST_OK" in r.stdout
