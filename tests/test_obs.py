"""``repro.obs`` contract tests.

Three things the observability subsystem promises, each pinned here:

1. **Exposition golden.**  The Prometheus text format is an interchange
   contract (a scraper parses it byte-by-byte), so it is golden-tested
   on a private :class:`Registry` -- counter/gauge/histogram rendering,
   label escaping, cumulative ``le`` buckets, ``+Inf`` overflow.
2. **Bitwise identity.**  All recording is host-side: an instrumented
   solve returns EXACTLY the bits of a bare one (``obs.disabled()``),
   single-RHS and batched, locally and (smoke, ``dist`` marker) on a
   forced 4-device mesh.
3. **Deterministic time.**  Every host-side timing path reads the one
   injectable clock, so installing a :class:`FakeClock` makes latency
   histograms, span durations and straggler detection exact.
"""

import os
import subprocess
import sys

import numpy as np
import pytest

from repro import obs
from repro.core import AzulEngine, SolveSpec
from repro.data.matrices import laplacian_2d
from repro.obs.clock import FakeClock

TOL = 1e-8


# -- exposition golden --------------------------------------------------------


def test_prometheus_golden_exact_text():
    reg = obs.Registry()
    c = reg.counter("jobs_total", "jobs processed", ("queue",))
    c.inc(3, queue="fast")
    c.inc(queue='we"ird')                      # label escaping
    reg.gauge("depth", "current queue depth").set(2.5)
    h = reg.histogram("lat_seconds", "latency", buckets=(0.01, 0.1, 1.0))
    h.observe(0.005)                           # first bucket
    h.observe(0.5)                             # third bucket
    h.observe(50.0)                            # +Inf overflow
    want = "\n".join([
        "# HELP depth current queue depth",
        "# TYPE depth gauge",
        "depth 2.5",
        "# HELP jobs_total jobs processed",
        "# TYPE jobs_total counter",
        'jobs_total{queue="fast"} 3',
        'jobs_total{queue="we\\"ird"} 1',
        "# HELP lat_seconds latency",
        "# TYPE lat_seconds histogram",
        'lat_seconds_bucket{le="0.01"} 1',
        'lat_seconds_bucket{le="0.1"} 1',
        'lat_seconds_bucket{le="1"} 2',
        'lat_seconds_bucket{le="+Inf"} 3',
        "lat_seconds_sum 50.505",
        "lat_seconds_count 3",
    ]) + "\n"
    assert obs.render_prometheus(reg) == want


def test_snapshot_roundtrips_the_same_registry():
    reg = obs.Registry()
    reg.counter("a_total", "a").inc(2)
    reg.histogram("h", "h", buckets=(1.0,)).observe(3.0)
    snap = obs.snapshot(reg)
    assert snap["a_total"]["samples"][0]["value"] == 2
    assert snap["h"]["samples"][0] == {
        "labels": {}, "sum": 3.0, "count": 1,
        "buckets": {"1": 0}, "overflow": 1}


def test_registry_idempotent_and_mismatch_raises():
    reg = obs.Registry()
    a = reg.counter("x_total", "x", ("k",))
    assert reg.counter("x_total", "x", ("k",)) is a
    with pytest.raises(ValueError):
        reg.gauge("x_total", "x", ("k",))          # kind mismatch
    with pytest.raises(ValueError):
        reg.counter("x_total", "x", ("other",))    # label mismatch
    with pytest.raises(ValueError):
        a.inc(-1, k="v")                           # counters only go up


def test_histogram_quantile_and_disabled_noop():
    h = obs.Registry().histogram("q", "q", buckets=(0.1, 1.0, 10.0))
    for v in (0.05, 0.5, 0.5, 5.0):
        h.observe(v)
    assert h.quantile(0.5) == 1.0
    assert h.quantile(0.99) == 10.0
    with obs.disabled():
        h.observe(100.0)                       # dropped
    assert h._default().count == 4


# -- bitwise identity ---------------------------------------------------------


def _solve_pair(spec_kwargs, b):
    """(instrumented bits, bare bits) from the SAME warm plan."""
    eng = AzulEngine(laplacian_2d(16), precond="jacobi", dtype=np.float64)
    plan = eng.plan(SolveSpec(**spec_kwargs))
    plan(b)                                     # warm (compile outside arms)
    x_on = np.asarray(plan(b)[0])
    with obs.disabled():
        x_off = np.asarray(plan(b)[0])
    return x_on, x_off


def test_instrumented_solve_bitwise_identical_single():
    rng = np.random.default_rng(0)
    b = rng.standard_normal(laplacian_2d(16).shape[0])
    x_on, x_off = _solve_pair(dict(method="pcg", iters=40), b)
    assert np.array_equal(x_on, x_off)


def test_instrumented_solve_bitwise_identical_batched():
    rng = np.random.default_rng(1)
    b = rng.standard_normal((3, laplacian_2d(16).shape[0]))
    x_on, x_off = _solve_pair(dict(method="pcg", iters=40, batch=3), b)
    assert np.array_equal(x_on, x_off)


def test_solve_instrumentation_records_metrics_and_spans():
    before = obs.REGISTRY.counter(
        "repro_solve_executions_total", "", ("method",)).value(method="pcg")
    obs.TRACER.clear()
    eng = AzulEngine(laplacian_2d(8), precond="jacobi", dtype=np.float64)
    plan = eng.plan(SolveSpec(method="pcg", iters=10))
    plan(np.ones(eng.n))
    plan(np.ones(eng.n))
    after = obs.REGISTRY.counter(
        "repro_solve_executions_total", "", ("method",)).value(method="pcg")
    assert after - before == 2
    counts = obs.TRACER.counts()
    assert counts.get("solve", 0) >= 2
    assert counts.get("plan_build", 0) >= 1
    # the lazy HLO summary must not count as a plan retrace
    tr = plan.traces
    assert plan.hlo_summary() == {"count_by_op": {}, "total_count": 0.0}
    assert plan.traces == tr
    plan.assert_steady()


_DIST_SCRIPT = """
import numpy as np
import jax
jax.config.update("jax_enable_x64", True)
from repro import obs
from repro.core import AzulEngine, SolveSpec
from repro.data.matrices import laplacian_2d
from repro.launch.mesh import make_mesh

mesh = make_mesh((4, 1), ("data", "model"))
m = laplacian_2d(16)
eng = AzulEngine(m, mesh=mesh, mode="1d", precond="jacobi",
                 dtype=np.float64)
b = np.random.default_rng(0).standard_normal(m.shape[0])
plan = eng.plan(SolveSpec(method="pcg", iters=30, layout="halo"))
plan(b)
x_on = np.asarray(plan(b)[0])
with obs.disabled():
    x_off = np.asarray(plan(b)[0])
assert np.array_equal(x_on, x_off), "dist obs-on/off bits diverged"
assert obs.REGISTRY.counter(
    "repro_solve_executions_total", "", ("method",)).value(method="pcg") == 2
print("OBS_DIST_OK")
"""


@pytest.mark.dist
def test_obs_bitwise_identity_multidevice():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = "src"
    env["JAX_ENABLE_X64"] = "1"
    r = subprocess.run(
        [sys.executable, "-c", _DIST_SCRIPT],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(__file__)), timeout=560,
    )
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr[-3000:]}"
    assert "OBS_DIST_OK" in r.stdout


# -- deterministic time (FakeClock) -------------------------------------------


def test_fake_clock_makes_spans_and_histograms_exact():
    tracer = obs.Tracer(capacity=8)
    h = obs.Registry().histogram("t", "t", buckets=(0.1, 1.0))
    with obs.clock.override(FakeClock()) as fake:
        with tracer.span("work", kind="chunk") as s:
            fake.advance(0.25)
        h.observe(obs.clock.now() - s.start)
    assert s.duration == 0.25
    assert h.quantile(0.5) == 1.0              # 0.25 lands in the 1.0 bucket
    # ring bound: capacity+1 spans -> exactly one dropped
    tracer.clear()
    with obs.clock.override(FakeClock()):
        for i in range(9):
            with tracer.span(f"s{i}", kind="x"):
                pass
    assert len(tracer.spans()) == 8 and tracer.dropped == 1


def test_fake_clock_sleep_advances_instead_of_blocking():
    with obs.clock.override(FakeClock(start=100.0)) as fake:
        t0 = obs.clock.now()
        obs.clock.sleep(5.0)
        assert obs.clock.now() - t0 == 5.0
        assert fake.now() == 105.0


def test_step_timer_straggler_detection_deterministic():
    from repro.ft.straggler import StepTimer

    timer = StepTimer(window=50, deadline_factor=2.0)
    with obs.clock.override(FakeClock()) as fake:
        for i in range(6):                     # steady 0.1 s steps
            with timer.timing(i):
                fake.advance(0.1)
        assert timer.last_report.is_straggler is False
        with timer.timing(6):                  # 10x blowout
            fake.advance(1.0)
    rep = timer.last_report
    assert rep.is_straggler is True
    assert rep.duration == 1.0 and rep.median == 0.1
    assert rep.shed_advice == 1


def test_chrome_trace_export(tmp_path):
    tracer = obs.Tracer()
    with obs.clock.override(FakeClock(start=1.0)) as fake:
        with tracer.span("solve", kind="solve", matrix="lap2d_16"):
            fake.advance(0.5)
    path = tmp_path / "trace.json"
    assert tracer.export_chrome(str(path)) == 1
    import json

    ev = json.loads(path.read_text())["traceEvents"][0]
    assert ev == {"name": "solve", "cat": "solve", "ph": "X",
                  "ts": 1.0e6, "dur": 0.5e6, "pid": 0, "tid": 0,
                  "args": {"matrix": "lap2d_16"}}


# -- HTTP exposition ----------------------------------------------------------


def test_metrics_server_serves_all_three_endpoints():
    import json
    import urllib.request

    reg = obs.Registry()
    reg.counter("up_total", "u").inc(7)
    tracer = obs.Tracer()
    with tracer.span("s", kind="tick"):
        pass
    with obs.start_metrics_server(port=0, registry=reg,
                                  tracer=tracer) as srv:
        base = f"http://{srv.host}:{srv.port}"
        with urllib.request.urlopen(f"{base}/metrics") as r:
            assert r.headers["Content-Type"].startswith("text/plain")
            assert b"up_total 7" in r.read()
        with urllib.request.urlopen(f"{base}/metrics.json") as r:
            assert json.load(r)["up_total"]["samples"][0]["value"] == 7
        with urllib.request.urlopen(f"{base}/trace.json") as r:
            assert len(json.load(r)["traceEvents"]) == 1
