"""Partition invariants: every nnz lands in exactly one tile; splits are
monotone and load-balanced; 2D plan reconstructs the matrix."""

import numpy as np
import scipy.sparse as sp
from _hypothesis_compat import given, settings, strategies as st

from repro.core.formats import csr_from_scipy
from repro.core.partition import (
    partition_nnz_histogram, plan_1d, plan_2d, split_rows,
)


def _mat(n, density, seed):
    a = sp.random(n, n, density=density, random_state=seed, format="csr")
    a.setdiag(1.0)
    return csr_from_scipy(a.tocsr())


@given(st.integers(8, 80), st.integers(1, 8), st.floats(0.02, 0.3),
       st.integers(0, 10**6), st.sampled_from(["rows", "nnz"]))
@settings(max_examples=25, deadline=None)
def test_split_rows_partition(n, parts, density, seed, balance):
    m = _mat(n, density, seed)
    offs = split_rows(m, parts, balance)
    assert offs[0] == 0 and offs[-1] == n
    assert (np.diff(offs) >= 0).all()
    # union of chunks covers all rows exactly once by construction
    hist = partition_nnz_histogram(m, offs)
    assert hist.sum() == m.nnz


def test_nnz_balance_beats_rows_on_skewed():
    # arrow matrix: last row dense -> nnz balancing shifts the split
    n = 64
    d = np.eye(n)
    d[-1, :] = 1.0
    a = sp.csr_matrix(d)
    m = csr_from_scipy(a)
    h_rows = partition_nnz_histogram(m, split_rows(m, 4, "rows"))
    h_nnz = partition_nnz_histogram(m, split_rows(m, 4, "nnz"))
    assert h_nnz.max() <= h_rows.max()


def _reconstruct_1d(p, n):
    acc = np.zeros((n, n))
    offs = p.row_offsets
    cols = np.asarray(p.cols)
    vals = np.asarray(p.vals)
    for t in range(p.parts):
        r0, r1 = int(offs[t]), int(offs[t + 1])
        for r in range(r1 - r0):
            for k in range(vals.shape[2]):
                if vals[t, r, k] != 0:
                    acc[r0 + r, cols[t, r, k]] += vals[t, r, k]
    return acc


@given(st.integers(8, 48), st.integers(1, 6), st.floats(0.05, 0.3),
       st.integers(0, 10**6))
@settings(max_examples=10, deadline=None)
def test_plan_1d_every_nnz_exactly_once(n, parts, density, seed):
    m = _mat(n, density, seed)
    import scipy.sparse as sp2
    dense = np.asarray(
        sp2.csr_matrix((m.data, m.indices, m.indptr), shape=m.shape).todense()
    )
    p = plan_1d(m, parts, dtype=np.float64)
    assert np.allclose(_reconstruct_1d(p, n), dense)


@given(st.integers(8, 40), st.sampled_from([(1, 1), (2, 2), (2, 4), (4, 2)]),
       st.floats(0.05, 0.3), st.integers(0, 10**6))
@settings(max_examples=10, deadline=None)
def test_plan_2d_every_nnz_exactly_once(n, grid, density, seed):
    pr, pc = grid
    m = _mat(n, density, seed)
    import scipy.sparse as sp2
    dense = np.asarray(
        sp2.csr_matrix((m.data, m.indices, m.indptr), shape=m.shape).todense()
    )
    p = plan_2d(m, pr, pc, dtype=np.float64)
    br, bc = p.block_rows, p.block_cols
    acc = np.zeros((p.n_padded, p.n_padded))
    cols = np.asarray(p.cols)
    vals = np.asarray(p.vals)
    for i in range(pr):
        for j in range(pc):
            t = i * pc + j
            for r in range(br):
                for k in range(vals.shape[2]):
                    if vals[t, r, k] != 0:
                        acc[i * br + r, j * bc + cols[t, r, k]] += vals[t, r, k]
    assert np.allclose(acc[:n, :n], dense)
    assert np.allclose(acc[n:, :], 0) and np.allclose(acc[:, n:], 0)
    # vector subsegment u must be whole (SUMMA shard uniformity)
    assert p.n_padded % (pr * pc) == 0
