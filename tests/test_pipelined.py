"""Pipelined PCG as a first-class method (PR 6).

Local coverage for the promoted ``pcg_pipelined`` / ``pcg_pipelined_tol``
solvers -- the Chronopoulos--Gear recurrence with ONE stacked reduction
per iteration:

* the ``pcg_pipe`` alias collapses onto the canonical plan-cache slot;
* breakdown guards: a zero RHS (gamma = delta = 0) produces exact zeros,
  never NaN, in fixed-iteration, tolerance and batched modes;
* the convergence trace is the TRUE residual norm ``||b - A x||`` -- the
  regression for the old surrogate ``sqrt((r, M^-1 r))`` trace, which
  under jacobi differs by ~sqrt(diag);
* fused and reference lowerings of the tolerance variant stop at the
  SAME iteration (the registry's iteration-count equality contract).

The multi-device checks (r0 reduced under ``shard_map`` -- the injected-
reduction regression; halo-overlap == dense bitwise; one all-reduce per
iteration asserted from the lowered HLO) run in a subprocess on a forced
host-device mesh, marked ``slow``/``dist`` like the commplan smoke.
"""

import os
import subprocess
import sys

import numpy as np
import pytest
import scipy.sparse as sp

from repro.core.engine import AzulEngine
from repro.core.plan import SolveSpec
from repro.data.matrices import laplacian_2d


def _setup(n=14, precond="jacobi"):
    m = laplacian_2d(n)
    a = sp.csr_matrix((m.data, m.indices, m.indptr), shape=m.shape)
    eng = AzulEngine(m, mesh=None, precond=precond, dtype=np.float64)
    rng = np.random.default_rng(6)
    x_true = rng.standard_normal(m.shape[0])
    return a, eng, x_true, a @ x_true


# -- alias / registry ---------------------------------------------------------


def test_alias_collapses_to_one_plan_cache_slot():
    """'pcg_pipe' is the pre-promotion spelling: canonicalization rewrites
    it to 'pcg_pipelined', so both spellings hit the SAME compiled plan."""
    _, eng, _, _ = _setup()
    p1 = eng.plan(SolveSpec(method="pcg_pipe", iters=20))
    p2 = eng.plan(SolveSpec(method="pcg_pipelined", iters=20))
    assert p1 is p2
    assert p1.spec.method == "pcg_pipelined"
    assert len(eng.plans) == 1


# -- breakdown guards ---------------------------------------------------------


def test_zero_rhs_fixed_iters_no_nan():
    """b = 0 drives gamma = delta = 0 through every iteration: the guarded
    scalars must yield alpha = beta = 0, not 0/0 NaN."""
    _, eng, _, _ = _setup()
    z = np.zeros(eng.n)
    x, norms = eng.plan(SolveSpec(method="pcg_pipelined", iters=30))(z)
    assert np.all(np.asarray(x) == 0.0)
    assert np.all(np.asarray(norms) == 0.0)
    assert np.all(np.isfinite(np.asarray(norms)))


def test_zero_rhs_tolerance_converges_at_zero_iters():
    _, eng, _, _ = _setup()
    plan = eng.plan(SolveSpec(method="pcg_pipelined_tol", tol=1e-10,
                              max_iters=50))
    x, norms = plan(np.zeros(eng.n))
    assert int(np.asarray(plan.last_iters)) == 0
    assert np.all(np.asarray(x) == 0.0)
    assert np.all(np.asarray(norms) == 0.0)


def test_zero_rhs_batched_column_stays_finite():
    """A zero column inside a batch must not poison its neighbours."""
    a, eng, x_true, b = _setup()
    B = np.stack([b, np.zeros(eng.n)])
    plan = eng.plan(SolveSpec(method="pcg_pipelined_tol", tol=1e-9,
                              max_iters=300, batch=2))
    x, norms = plan(B)
    its = np.asarray(plan.last_iters)
    assert its[1] == 0 and 0 < its[0] < 300
    assert np.all(np.asarray(norms)[:, 1] == 0.0)
    np.testing.assert_allclose(np.asarray(x)[0], x_true, atol=1e-6)
    assert np.all(np.asarray(x)[1] == 0.0)


# -- the trace is the true residual -------------------------------------------


def test_trace_is_true_residual_norm():
    """Regression for the surrogate trace: the old pcg_pipe recorded
    ``sqrt((r, M^-1 r))``, which under jacobi on a Laplacian is off by
    ~``sqrt(diag)=2``; the promoted method traces ``||b - A x||``."""
    a, eng, _, b = _setup(precond="jacobi")
    plan = eng.plan(SolveSpec(method="pcg_pipelined", iters=25))
    x, norms = plan(b)
    norms = np.asarray(norms)
    assert norms[0] == pytest.approx(np.linalg.norm(b), rel=1e-12)
    true_final = np.linalg.norm(b - a @ np.asarray(x))
    assert norms[-1] == pytest.approx(true_final, rel=1e-6)
    # and it matches the standard pcg trace (same math, same norm)
    _, n_ref = eng.plan(SolveSpec(method="pcg", iters=25))(b)
    np.testing.assert_allclose(norms, np.asarray(n_ref), rtol=1e-5,
                               atol=1e-12)


# -- fused == reference iteration counts --------------------------------------


@pytest.mark.parametrize("precond", ["jacobi", "none"])
def test_tolerance_fused_vs_reference_iteration_parity(precond):
    a, eng, x_true, b = _setup(precond=precond)
    tf = eng.plan(SolveSpec(method="pcg_pipelined_tol", tol=1e-9,
                            max_iters=400, fused=True))
    tr = eng.plan(SolveSpec(method="pcg_pipelined_tol", tol=1e-9,
                            max_iters=400, fused=False))
    assert tf.info["substrate"] != "reference"
    assert tr.info["substrate"] == "reference"
    xf, _ = tf(b)
    xr, _ = tr(b)
    assert np.array_equal(np.asarray(tf.last_iters),
                          np.asarray(tr.last_iters))
    np.testing.assert_allclose(np.asarray(xf), np.asarray(xr), atol=1e-10)
    np.testing.assert_allclose(np.asarray(xf), x_true, atol=1e-6)


# -- multi-device end to end (small-mesh PR smoke) ----------------------------

_SCRIPT = r"""
import numpy as np
import scipy.sparse as sp
from repro.core.engine import AzulEngine
from repro.core.plan import SolveSpec
from repro.data.matrices import laplacian_2d
from repro.launch.mesh import make_mesh

m = laplacian_2d(16)                  # n=256, banded
n = m.shape[0]
A = sp.csr_matrix((m.data, m.indices, m.indptr), shape=m.shape)
rng = np.random.default_rng(1)
xt = rng.standard_normal(n); b = A @ xt
Xt = rng.standard_normal((3, n)); Bk = Xt @ A.toarray().T
bn = np.linalg.norm(b)

mesh = make_mesh((4, 1), ("data", "model"))
for mode in ("1d", "2d"):
    eng = AzulEngine(m, mesh=mesh, mode=mode, precond="jacobi",
                     dtype=np.float64)
    assert eng.comm_plan.use_halo, mode

    ph = eng.plan(SolveSpec(method="pcg_pipelined", iters=60, layout="halo"))
    pd = eng.plan(SolveSpec(method="pcg_pipelined", iters=60, layout="dense"))
    xh, nh = ph(b); xd, nd = pd(b)

    # r0 regression: the init reduction runs through the injected psum'd
    # pdots -- the trace head is the GLOBAL ||b||, not one shard's slice
    assert np.isclose(np.asarray(nh)[0], bn, rtol=1e-10), (mode, "r0 halo")
    assert np.isclose(np.asarray(nd)[0], bn, rtol=1e-10), (mode, "r0 dense")

    # the communication-hiding split matvec is pure re-association of the
    # same per-slot products: halo-overlap == dense BITWISE
    assert np.array_equal(xh, xd), (mode, "x halo!=dense")
    assert np.array_equal(nh, nd), (mode, "norms halo!=dense")
    assert np.allclose(np.asarray(xh), xt, atol=1e-6), mode

    # the overlap lowering is recorded in the plan's NoC model
    noc = ph.info["noc"]
    assert noc["comm_overlap"] is True, mode
    assert 0.0 <= noc["overlap_efficiency"] <= 1.0
    assert noc["overlap_hidden_words"] + noc["overlap_exposed_words"] \
        == noc["gather_words_halo"]
    assert 0.0 < noc["interior_frac_nnz"] <= 1.0
    assert eng.plan(SolveSpec(method="pcg", iters=60, layout="halo")
                    ).info["noc"]["comm_overlap"] is False

    # batched RHS: same bitwise identity
    phb = eng.plan(SolveSpec(method="pcg_pipelined", iters=60,
                             layout="halo", batch=3))
    pdb = eng.plan(SolveSpec(method="pcg_pipelined", iters=60,
                             layout="dense", batch=3))
    xhb, nhb = phb(Bk); xdb, ndb = pdb(Bk)
    assert np.array_equal(xhb, xdb), (mode, "batched x")
    assert np.array_equal(nhb, ndb), (mode, "batched norms")

    # tolerance mode: halo-overlap stops at the SAME iteration as dense
    th = eng.plan(SolveSpec(method="pcg_pipelined_tol", tol=1e-9,
                            max_iters=200, layout="halo"))
    td = eng.plan(SolveSpec(method="pcg_pipelined_tol", tol=1e-9,
                            max_iters=200, layout="dense"))
    xth, _ = th(b); xtd, _ = td(b)
    assert np.array_equal(np.asarray(th.last_iters),
                          np.asarray(td.last_iters)), mode
    assert np.allclose(np.asarray(xth), xt, atol=1e-6), mode

# ONE collective per iteration, asserted from the lowered HLO: the fixed-
# iteration pipelined program contains exactly TWO all-reduces total (the
# init pdots + the scan-body pdots), while standard pcg carries its two
# split reductions per iteration (4 all-reduces).  The halo matvec itself
# lowers to collective-permutes, never all-reduce/all-gather.
eng = AzulEngine(m, mesh=mesh, mode="1d", precond="jacobi", dtype=np.float64)
def collectives(plan):
    ops = plan.hlo_summary()["count_by_op"]
    return (int(ops.get("all-reduce", 0)),
            int(ops.get("collective-permute", 0)),
            int(ops.get("all-gather", 0)))
pl = eng.plan(SolveSpec(method="pcg_pipelined", iters=60, layout="halo"))
ar, cp_, ag = collectives(pl)
assert ar == 2, f"pipelined halo all_reduce {ar} != 2"
assert ag == 0 and cp_ > 0, (cp_, ag)
assert pl.info["hlo"]["count_by_op"], "hlo_summary not cached into info"
ar_pcg, _, _ = collectives(eng.plan(SolveSpec(method="pcg", iters=60,
                                              layout="halo")))
assert ar_pcg == 4, f"pcg halo all_reduce {ar_pcg} != 4"

print("PIPELINED_DIST_OK")
"""


@pytest.mark.slow
@pytest.mark.dist
def test_pipelined_multidevice_small_mesh():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = "src"
    env["JAX_ENABLE_X64"] = "1"
    r = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(__file__)), timeout=560,
    )
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr[-3000:]}"
    assert "PIPELINED_DIST_OK" in r.stdout
