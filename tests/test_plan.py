"""Plan/execute API: spec canonicalization, PlanCache hit/miss, the
zero-recompile execution contract (including SolveServer steady state),
the deprecated ``engine.solve(**knobs)`` shim, the bounded tolerance
convergence trace, and registry extensibility."""

import warnings

import numpy as np
import pytest
import scipy.sparse as sp

from repro.core import (
    AzulEngine,
    SolveSpec,
    SolverDef,
    register_solver,
    solver_names,
    precond_names,
)
from repro.core.plan import _reset_deprecation_warnings
from repro.core.registry import unregister_solver
from repro.data.matrices import laplacian_2d
from repro.serve import SolveServer


def _setup(n=10, precond="jacobi"):
    m = laplacian_2d(n)
    a = sp.csr_matrix((m.data, m.indices, m.indptr), shape=m.shape)
    eng = AzulEngine(m, precond=precond, dtype=np.float64)
    b = a @ np.random.default_rng(0).standard_normal(m.shape[0])
    return m, a, eng, b


# -- PlanCache: spec-keyed hit/miss ------------------------------------------


def test_plan_cache_spec_keyed_hit_miss():
    _, _, eng, b = _setup()
    p1 = eng.plan(SolveSpec(method="pcg", iters=30))
    assert eng.plans.misses == 1 and eng.plans.hits == 0
    # equal configuration -> the SAME plan object, however it is spelled
    assert eng.plan(SolveSpec(method="pcg", iters=30)) is p1
    assert eng.plan(method="pcg", iters=30) is p1
    assert eng.plan(SolveSpec(method="pcg", iters=30, precond="jacobi")) is p1
    assert eng.plans.hits == 3
    # different configuration -> a different plan
    p2 = eng.plan(SolveSpec(method="pcg", iters=31))
    assert p2 is not p1
    p3 = eng.plan(SolveSpec(method="cg", iters=30))
    assert p3 is not p1
    assert len(eng.plans) == 3
    # canonical spec membership (layout/reorder/format resolved alike)
    assert SolveSpec(method="pcg", precond="jacobi", iters=30,
                     fused=True, layout="dense", reorder="none",
                     format="ell") in eng.plans


def test_tol_changes_never_recompile_fixed_iteration_plans():
    """The PR 3 cache-key special case, now structural: canonicalization
    nulls tol/max_iters on fixed-iteration methods, so a tol change can
    never lower (or recompile) a bit-identical pcg plan."""
    _, _, eng, b = _setup()
    p = eng.plan(SolveSpec(method="pcg", iters=25, tol=1e-3, max_iters=99))
    assert p.spec.tol is None and p.spec.max_iters is None
    for tol in (1e-2, 1e-8, 0.5):
        assert eng.plan(SolveSpec(method="pcg", iters=25, tol=tol)) is p
    assert len(eng.plans) == 1
    # tolerance methods DO key on (tol, max_iters) -- distinct programs
    t1 = eng.plan(SolveSpec(method="pcg_tol", tol=1e-6, max_iters=50))
    t2 = eng.plan(SolveSpec(method="pcg_tol", tol=1e-8, max_iters=50))
    t3 = eng.plan(SolveSpec(method="pcg_tol", tol=1e-6, max_iters=60))
    assert len({id(t1), id(t2), id(t3)}) == 3
    # ... and iters folds into max_iters (one budget field)
    t4 = eng.plan(SolveSpec(method="pcg_tol", tol=1e-6, iters=50))
    assert t4 is t1


def test_spec_validation():
    _, _, eng, _ = _setup(precond="jacobi")
    with pytest.raises(ValueError, match="unknown solver"):
        eng.plan(SolveSpec(method="sor"))
    with pytest.raises(ValueError, match="engine precond"):
        eng.plan(SolveSpec(method="pcg", precond="block_ic0"))
    with pytest.raises(ValueError, match="batch"):
        eng.plan(SolveSpec(method="pcg", batch=0))
    with pytest.raises(ValueError, match="fused"):
        eng.plan(SolveSpec(method="pcg", fused="maybe"))
    # "none" aliases to the registry's canonical "identity"
    m = laplacian_2d(8)
    e2 = AzulEngine(m, precond="none", dtype=np.float64)
    assert e2.plan(SolveSpec(method="pcg")).spec.precond == "identity"


# -- the zero-recompile contract ---------------------------------------------


def test_one_trace_per_plan_across_100_executions():
    _, _, eng, b = _setup()
    plan = eng.plan(SolveSpec(method="pcg", iters=5))
    x0, n0 = plan(b)
    for _ in range(99):
        x, norms = plan(b)
    assert plan.executions == 100
    assert plan.traces == 1, "plan retraced -- the compile-once contract broke"
    np.testing.assert_array_equal(x, x0)


def test_plans_are_shape_specialized():
    _, _, eng, b = _setup()
    plan = eng.plan(SolveSpec(method="pcg", iters=5, batch=4))
    with pytest.raises(ValueError, match="shape-specialized"):
        plan(b)                                  # (n,) into a batch-4 plan
    with pytest.raises(ValueError, match="shape-specialized"):
        plan(np.stack([b, b]))                   # (2, n) into a batch-4 plan
    x, norms = plan(np.stack([b] * 4))
    assert x.shape == (4, eng.n) and norms.shape == (6, 4)
    # shared (n,) x0 broadcasts over the batch
    x2, _ = plan(np.stack([b] * 4), x0=np.zeros(eng.n))
    np.testing.assert_array_equal(x2, x)


def test_solve_server_steady_state_zero_recompiles():
    """100 server steps across two batch buckets: one plan per bucket,
    each traced exactly once -- dispatch resolves at plan construction,
    never per step."""
    _, a, eng, _ = _setup()
    srv = SolveServer(eng, max_batch=4,
                      spec=SolveSpec(method="pcg", iters=5))
    rng = np.random.default_rng(3)
    xt = rng.standard_normal((100, eng.n))
    done = {}
    for i in range(80):                      # bucket k=1, 80 steps
        srv.submit(a @ xt[i])
        done.update(srv.step())
    for i in range(80, 100, 4):              # bucket k=4, 5 steps
        for j in range(4):
            srv.submit(a @ xt[i + j])
        done.update(srv.step())
    assert len(done) == 100
    assert srv.stats["batches"] == 85
    assert srv.stats["plans"] == 2           # one plan per bucket, total
    for k_pad, plan in srv._plans.items():
        assert plan.traces == 1, f"bucket {k_pad} retraced"
    assert srv._plans[1].executions == 80
    assert srv._plans[4].executions == 5


def test_solve_server_tolerance_outcomes_carry_trace():
    _, a, eng, _ = _setup()
    srv = SolveServer(eng, max_batch=4,
                      spec=SolveSpec(method="pcg_tol", tol=1e-9, max_iters=60))
    rng = np.random.default_rng(4)
    xt = rng.standard_normal((3, eng.n))
    ids = [srv.submit(a @ xt[i]) for i in range(3)]
    done = srv.drain()
    # the batch loop runs until EVERY RHS converges; the ring tail-fills
    # from that global stopping iteration
    kmax = max(done[rid].iters for rid in ids)
    for i, rid in enumerate(ids):
        out = done[rid]
        np.testing.assert_allclose(out.x, xt[i], atol=1e-6)
        assert 0 < out.iters <= 60
        # the bounded ring: full (max_iters + 1,) trace, tail-filled
        assert out.res_norms.shape == (61,)
        assert np.all(out.res_norms[kmax:] == out.res_norms[kmax])


# -- deprecation shims --------------------------------------------------------


def test_solve_shim_warns_once_and_is_bit_identical():
    _, _, eng, b = _setup()
    plan = eng.plan(SolveSpec(method="pcg_tol", tol=1e-8, max_iters=80))
    xp, np_ = plan(b)
    _reset_deprecation_warnings()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        xs, ns = eng.solve(b, method="pcg_tol", tol=1e-8, max_iters=80)
        xs2, ns2 = eng.solve(b, method="pcg_tol", tol=1e-8, max_iters=80)
    deps = [x for x in w if issubclass(x.category, DeprecationWarning)]
    assert len(deps) == 1, "legacy solve must warn exactly once per process"
    assert "SolveSpec" in str(deps[0].message)
    # bit-identical: the shim hits the same cached plan and program
    np.testing.assert_array_equal(xs, xp)
    np.testing.assert_array_equal(ns, np_)
    np.testing.assert_array_equal(xs2, xp)
    assert len(eng.plans) == 1


def test_solve_shim_batched_routes_through_batch_plan():
    _, a, eng, _ = _setup()
    rng = np.random.default_rng(5)
    B = rng.standard_normal((3, eng.n)) @ a.T
    xs, ns = eng.solve(B, method="pcg", iters=20)
    # membership takes the CANONICAL spec (precond resolved, fused bool)
    canonical = SolveSpec(method="pcg", precond="jacobi", iters=20,
                          batch=3, fused=True, layout="dense",
                          reorder="none", format="ell")
    assert canonical in eng.plans
    plan = eng.plan(SolveSpec(method="pcg", iters=20, batch=3))
    assert plan.executions == 1              # the shim's execution
    xp, npn = plan(B)
    np.testing.assert_array_equal(xs, xp)


# -- bounded tolerance trace (plan output) -----------------------------------


def test_pcg_tol_plan_returns_bounded_trace():
    _, _, eng, b = _setup()
    plan = eng.plan(SolveSpec(method="pcg_tol", tol=1e-9, max_iters=70))
    x, norms = plan(b)
    it = int(plan.last_iters)
    assert 0 < it < 70
    assert norms.shape == (71,)
    assert norms[0] == pytest.approx(np.linalg.norm(b))
    # real trace decreases to tolerance; tail is the final residual
    assert norms[it] < 1e-8 * np.linalg.norm(b)
    assert np.all(norms[it:] == norms[it])
    assert norms[-1] == norms[it]


def test_pcg_tol_batched_trace_per_rhs():
    _, a, eng, _ = _setup()
    rng = np.random.default_rng(7)
    B = np.stack([a @ rng.standard_normal(eng.n), np.zeros(eng.n)])
    plan = eng.plan(SolveSpec(method="pcg_tol", tol=1e-9, max_iters=80,
                              batch=2))
    x, norms = plan(B)
    assert norms.shape == (81, 2)
    its = np.asarray(plan.last_iters)
    assert its[1] == 0 and 0 < its[0] < 80
    assert np.all(norms[:, 1] == 0.0)        # zero RHS: zero residual ring


# -- registry extensibility ---------------------------------------------------


def test_registry_lists_builtins():
    assert {"cg", "pcg", "pcg_pipelined", "pcg_pipelined_tol", "pcg_tol",
            "jacobi"} <= set(solver_names())
    assert {"identity", "jacobi", "block_ic0"} <= set(precond_names())


def test_register_custom_solver_runs_through_plan():
    """Adding a method is a registry entry + the iteration it runs: the
    engine lowers it through the same generic path (no engine edits)."""
    import jax.numpy as jnp
    from jax import lax

    from repro.core.solvers import SolveResult

    def run_richardson(ctx, b, x0):
        omega = 0.8
        r0 = b - ctx.matvec(x0)
        n0 = jnp.sqrt(jnp.sum(r0 * r0))

        def step(x, _):
            r = b - ctx.matvec(x)
            x = x + omega * ctx.psolve(r)
            return x, jnp.sqrt(jnp.sum(r * r))

        x, norms = lax.scan(step, x0, None, length=ctx.iters)
        return SolveResult(x, jnp.concatenate([n0[None], norms]),
                           jnp.full(b.shape[:-1], ctx.iters, jnp.int32))

    register_solver(SolverDef(name="_test_richardson", run=run_richardson))
    try:
        _, _, eng, b = _setup()
        plan = eng.plan(SolveSpec(method="_test_richardson", iters=300))
        assert plan.info["substrate"] == "reference"  # registers no fused caps
        x, norms = plan(b)
        assert norms.shape == (301,)
        assert norms[-1] < 1e-6 * norms[0]
        assert plan.traces == 1
    finally:
        unregister_solver("_test_richardson")
    with pytest.raises(ValueError, match="unknown solver"):
        eng.plan(SolveSpec(method="_test_richardson"))
