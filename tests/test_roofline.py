"""HLO collective parser: synthetic-module unit tests + a live compile."""

import jax
import jax.numpy as jnp

from repro.roofline.collect import analyze_hlo_text

_SYNTH = """\
HloModule test, entry_computation_layout={()->f32[]}

%body.1 (arg: (s32[], f32[128,256])) -> (s32[], f32[128,256]) {
  %arg = (s32[], f32[128,256]) parameter(0)
  %x = f32[128,256] get-tuple-element(%arg), index=1
  %ag = f32[256,256] all-gather(%x), dimensions={0}
  %red = f32[128,256] all-reduce(%x), to_apply=%add.1
  ROOT %t = (s32[], f32[128,256]) tuple(%arg)
}

%cond.1 (arg2: (s32[], f32[128,256])) -> pred[] {
  %arg2 = (s32[], f32[128,256]) parameter(0)
  %i = s32[] get-tuple-element(%arg2), index=0
  %c = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

%add.1 (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main.1 () -> f32[] {
  %init = (s32[], f32[128,256]) tuple()
  %w = (s32[], f32[128,256]) while(%init), condition=%cond.1, body=%body.1
  %y = f32[512,128] parameter(0)
  %cp = f32[512,128] collective-permute(%y), source_target_pairs={{0,1}}
  ROOT %r = f32[] constant(0)
}
"""


def test_synthetic_while_multiplication():
    res = analyze_hlo_text(_SYNTH)
    x_bytes = 128 * 256 * 4
    # body (executed 12x): all-gather wire = output - operand = 2x - x = x;
    # all-reduce wire = 2 x operand (ring rs+ag phases)
    assert res["by_op"]["all-gather"] == 12 * x_bytes
    assert res["by_op"]["all-reduce"] == 12 * 2 * x_bytes
    # entry-level permute once, wire = operand
    assert res["by_op"]["collective-permute"] == 512 * 128 * 4
    assert res["whiles"] == {"body.1": 12}


def test_async_start_counted_done_ignored():
    text = _SYNTH.replace(
        "%red = f32[128,256] all-reduce(%x), to_apply=%add.1",
        "%red = (f32[128,256], f32[128,256]) all-reduce-start(%x), to_apply=%add.1\n"
        "  %red2 = f32[128,256] all-reduce-done(%red)",
    )
    res = analyze_hlo_text(text)
    assert res["by_op"]["all-reduce"] == 12 * 2 * 128 * 256 * 4


def test_live_single_device_module_has_no_collectives():
    f = jax.jit(lambda x: (x @ x).sum())
    compiled = f.lower(jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
    from repro.roofline.collect import analyze_compiled

    res = analyze_compiled(compiled)
    assert res["total_bytes"] == 0.0


def test_scan_trip_count_detected():
    def f(x):
        def body(c, _):
            return jnp.tanh(c), None
        y, _ = jax.lax.scan(body, x, None, length=17)
        return y

    compiled = jax.jit(f).lower(
        jax.ShapeDtypeStruct((8, 8), jnp.float32)
    ).compile()
    from repro.roofline.collect import analyze_compiled

    res = analyze_compiled(compiled)
    assert 17 in res["whiles"].values()
