"""SolveService (the always-on serving management plane): continuous
batching joins at chunk boundaries bitwise-identically to solo solves,
steady state never re-enters the compiler, the operator registry
admits/evicts/reloads under a memory budget, and admission control
rejects with structured reasons.  The legacy ``SolveServer`` shim stays
pinned to the plan surface."""

import warnings

import numpy as np
import pytest
import scipy.sparse as sp

from repro.core import AzulEngine, SolveSpec
from repro.core.plan import _reset_deprecation_warnings
from repro.data.matrices import laplacian_2d
from repro.serve import (
    SolveRequestError,
    SolveServer,
    SolveService,
)
from repro.serve.service import _Pending

TOL = 1e-8


def _csr(m):
    return sp.csr_matrix((m.data, m.indices, m.indptr), shape=m.shape)


def _rhs(n, seed=0):
    return np.random.default_rng(seed).standard_normal(n)


def _service(n=8, chunk=8, max_batch=4, tol=TOL, name="lap", **kw):
    m = laplacian_2d(n)
    svc = SolveService(max_batch=max_batch, chunk=chunk, **kw)
    svc.register_operator(name, m, method="pcg_tol", tol=tol, iters=400,
                          precond="jacobi", dtype=np.float64)
    return svc, m


def _all_pool_plans(svc):
    for op in svc._operators.values():
        for pool in op.pools.values():
            yield from pool.values()


# -- continuous batching: the bitwise mid-stream join invariant --------------


def test_midstream_join_bitwise_identical_to_solo():
    """A request that arrives while another solve is mid-flight joins at
    the next chunk boundary and produces the EXACT bits -- solution and
    residual trace -- of a solo solve of the same RHS."""
    m = laplacian_2d(8)
    n = m.shape[0]
    b_a, b_b = _rhs(n, 1), _rhs(n, 2)

    solo, _ = _service(8)
    rid = solo.submit(b_b)
    ref = solo.drain()[rid]
    assert ref.status == "converged"

    svc, _ = _service(8)
    ra = svc.submit(b_a)
    svc.tick()
    svc.tick()
    assert svc.active() == 1          # a genuinely mid-solve
    rb = svc.submit(b_b)              # joins at the next chunk boundary
    done = svc.drain()
    assert done[ra].status == "converged"
    got = done[rb]
    assert got.status == "converged"
    assert got.iters == ref.iters
    assert np.array_equal(got.x, ref.x)                    # bitwise
    assert np.array_equal(got.res_norms, ref.res_norms)    # bitwise
    assert svc.stats["rebuckets"] >= 1     # the cohort actually changed


def test_midstream_join_bitwise_multi_operator_and_zero_retraces():
    """The invariant holds with several tenants resident: traffic on
    operator A cannot perturb a solve on operator B, and the whole run
    never retraces any pool plan."""
    ma, mb = laplacian_2d(8), laplacian_2d(9)
    b_a = _rhs(ma.shape[0], 3)
    b_b = _rhs(mb.shape[0], 4)

    solo = SolveService(max_batch=4, chunk=8)
    solo.register_operator("B", mb, method="pcg_tol", tol=TOL, iters=400)
    rid = solo.submit(b_b, "B")
    ref = solo.drain()[rid]

    svc = SolveService(max_batch=4, chunk=8)
    svc.register_operator("A", ma, method="pcg_tol", tol=TOL, iters=400)
    svc.register_operator("B", mb, method="pcg_tol", tol=TOL, iters=400)
    ra = svc.submit(b_a, "A")
    svc.tick()                       # A mid-flight on its own lanes
    rb = svc.submit(b_b, "B")        # B joins while A keeps chunking
    done = svc.drain()
    assert done[ra].operator == "A" and done[rb].operator == "B"
    assert np.array_equal(done[rb].x, ref.x)
    assert np.array_equal(done[rb].res_norms, ref.res_norms)
    # compile-free steady state, both tenants
    for plan in _all_pool_plans(svc):
        assert plan.traces == 1


def test_steady_state_100_requests_zero_retraces():
    """The acceptance run: 100 requests stream through one operator with
    continuous re-bucketing, and every warm-pool plan traced exactly
    once -- the service never re-enters the compiler in steady state."""
    svc, m = _service(8, chunk=25, max_batch=8, tol=1e-6, queue_max=None)
    n = m.shape[0]
    rhs = np.random.default_rng(5).standard_normal((16, n))
    ids = [svc.submit(rhs[i % 16]) for i in range(100)]
    done = svc.drain()
    assert len(done) == 100
    assert all(done[r].status == "converged" for r in ids)
    plans = list(_all_pool_plans(svc))
    assert plans, "warm pool unexpectedly empty"
    for plan in plans:
        assert plan.traces == 1            # ZERO retraces, asserted
    # the pool stays bucket-bounded: at most one cb plan per power-of-two
    # bucket <= max_batch, not one per cohort
    assert svc.stats["plans"] <= 4
    assert svc.stats["chunks"] > len(plans)    # plans are genuinely reused
    a = _csr(m)
    for rid in ids[:5]:
        r = np.linalg.norm(rhs[ids.index(rid) % 16] - a @ done[rid].x)
        assert r <= 1e-6 * np.linalg.norm(rhs[ids.index(rid) % 16]) * 10


# -- admission control / backpressure ----------------------------------------


def test_structured_rejects():
    svc, m = _service(8, queue_max=2)
    n = m.shape[0]
    with pytest.raises(SolveRequestError) as ei:
        svc.submit(_rhs(n), "nope")
    assert ei.value.reason == "operator_unknown"
    with pytest.raises(SolveRequestError) as ei:
        svc.submit(_rhs(n + 1))
    assert ei.value.reason == "rhs_shape"
    bad = _rhs(n)
    bad[3] = np.nan
    with pytest.raises(SolveRequestError) as ei:
        svc.submit(bad)
    assert ei.value.reason == "rhs_nonfinite"
    with pytest.raises(SolveRequestError) as ei:
        svc.submit(_rhs(n), tol=-1.0)
    assert ei.value.reason == "tol"
    with pytest.raises(SolveRequestError) as ei:
        svc.submit(_rhs(n), max_iters=0)
    assert ei.value.reason == "max_iters"
    with pytest.raises(SolveRequestError) as ei:
        svc.submit(_rhs(n), deadline=-0.5)
    assert ei.value.reason == "deadline"
    svc.submit(_rhs(n))
    svc.submit(_rhs(n))
    with pytest.raises(SolveRequestError) as ei:
        svc.submit(_rhs(n))               # bounded queue pushes back
    assert ei.value.reason == "queue_full"
    assert svc.pending() == 2             # rejected request never enqueued
    assert svc.stats["rejected"] == 7
    assert svc.stats["rejects"]["queue_full"] == 1
    assert svc.stats["rejects"]["operator_unknown"] == 1
    svc.drain()


def test_admission_order_ages_old_low_priority_work():
    def mk(rid, pr, t, dl=None):
        return _Pending(rid=rid, op="o", b=None, tol=None, max_iters=None,
                        deadline=dl, priority=pr, t_submit=t)

    old_low = mk(0, 0.0, 0.0)          # waited 10s -> effective 10
    new_high = mk(1, 5.0, 9.5)         # effective 5.5
    new_deadline = mk(2, 0.0, 9.5, dl=1.0)   # deadline boost -> 1.5
    order = SolveService._admission_order(
        [new_deadline, new_high, old_low], now=10.0, aging=1.0)
    assert [p.rid for p in order] == [0, 1, 2]
    # aging disabled: raw priority wins, FIFO ties
    order = SolveService._admission_order(
        [old_low, new_high, new_deadline], now=10.0, aging=None)
    assert [p.rid for p in order] == [1, 2, 0]


def test_per_request_tol_and_max_iters_never_add_plans():
    """Per-request completion targets are host-side: a loose-tol and a
    tight-tol request share the SAME warm plan (no new compile)."""
    svc, m = _service(8, chunk=8)
    n = m.shape[0]
    r1 = svc.submit(_rhs(n, 7), tol=1e-3)
    loose = svc.drain()[r1]
    plans_after = svc.stats["plans"]
    r2 = svc.submit(_rhs(n, 7), tol=1e-11)
    tight = svc.drain()[r2]
    assert svc.stats["plans"] == plans_after     # no new plan for new tol
    assert loose.status == tight.status == "converged"
    assert loose.iters < tight.iters
    assert loose.rel_residual <= 1e-3
    assert tight.rel_residual <= 1e-11
    # per-request budget: tol=0 never converges host-side, the cap lands
    # at the first chunk boundary >= max_iters
    r3 = svc.submit(_rhs(n, 7), tol=0.0, max_iters=5)
    capped = svc.drain()[r3]
    assert capped.status == "maxiter"
    assert capped.iters >= 5
    assert svc.stats["plans"] == plans_after


def test_deadline_on_the_continuous_path():
    svc, m = _service(8, chunk=8)
    rid = svc.submit(_rhs(m.shape[0]), tol=1e-20, deadline=0.0)
    out = svc.drain()[rid]
    assert out.status == "deadline_exceeded"
    assert out.iters >= svc.chunk          # got at least one chunk of work
    assert svc.stats["deadline_exceeded"] == 1


# -- operator registry: memory accounting, LRU eviction, reload --------------


def test_lru_eviction_and_lazy_reload():
    big, small = laplacian_2d(10), laplacian_2d(6)
    svc = SolveService(max_batch=2, chunk=8)
    i_big = svc.register_operator("big", big, method="pcg_tol", tol=TOL,
                                  iters=400)
    # budget exactly the big operator: registering the small one must
    # evict "big" (idle, rebuildable) rather than reject
    svc.memory_limit = i_big.bytes
    svc.register_operator("small", small, method="pcg_tol", tol=TOL,
                          iters=400)
    snap = svc.operators()
    assert not snap["big"].resident and snap["small"].resident
    assert snap["big"].evictable            # host matrix kept
    assert svc.stats["evictions"] == 1
    assert svc.resident_bytes() <= svc.memory_limit
    # traffic to the evicted operator re-materializes it from the host
    # matrix (and evicts the other idle tenant to make room)
    rid = svc.submit(_rhs(big.shape[0], 9), "big")
    out = svc.drain()[rid]
    assert out.status == "converged"
    assert svc.stats["reloads"] == 1
    assert svc.operators()["big"].resident


def test_over_memory_reject_when_nothing_evictable():
    m = laplacian_2d(8)
    eng = AzulEngine(m, precond="jacobi", dtype=np.float64)
    svc = SolveService(max_batch=2, chunk=8,
                       memory_limit=int(eng.device_bytes()))
    # engine-registered operator: pinned (no host matrix to rebuild from)
    svc.register_operator("pinned", engine=eng,
                          spec=SolveSpec(method="pcg_tol", tol=TOL,
                                         iters=400))
    assert not svc.operators()["pinned"].evictable
    with pytest.raises(SolveRequestError) as ei:
        svc.register_operator("more", laplacian_2d(6), method="pcg_tol",
                              tol=TOL, iters=400)
    assert ei.value.reason == "over_memory"
    assert "more" not in svc.operators()


def test_unregister_refuses_busy_operator():
    svc, m = _service(8)
    rid = svc.submit(_rhs(m.shape[0]))
    svc.tick()
    assert svc.active() == 1
    with pytest.raises(ValueError, match="busy"):
        svc.unregister_operator("lap")
    svc.drain()
    svc.unregister_operator("lap")
    assert svc.operators() == {}
    assert rid is not None


# -- degradation and fixed-iteration methods on the continuous path ----------


class _BoomPlan:
    """Fused-plan double that explodes on execution (traces stays 1)."""

    info = {"fused": True}
    traces = 1

    def __init__(self):
        self.calls = 0

    def __call__(self, batch, x0=None):
        self.calls += 1
        raise RuntimeError("injected fused-kernel failure")


def test_degrades_to_reference_chunks_on_fused_failure():
    svc, m = _service(8, chunk=8)
    rid = svc.submit(_rhs(m.shape[0], 11))
    boom = _BoomPlan()
    svc._operators["lap"].pools["cb"][1] = boom   # poison bucket-1 chunks
    out = svc.drain()[rid]
    assert out.status == "converged"              # answered by cb_ref
    assert boom.calls >= 1
    assert svc.stats["degraded_batches"] >= 1
    a = _csr(m)
    b = _rhs(m.shape[0], 11)
    assert np.linalg.norm(b - a @ out.x) <= TOL * np.linalg.norm(b) * 10


def test_fixed_iteration_method_serves_in_chunks():
    m = laplacian_2d(8)
    svc = SolveService(max_batch=2, chunk=10)
    svc.register_operator("lap", m, method="pcg", iters=30, precond="jacobi",
                          dtype=np.float64)
    b = _rhs(m.shape[0], 13)
    rid = svc.submit(b)
    out = svc.drain()[rid]
    assert out.status == "maxiter"        # budget-terminated, healthy
    assert out.iters == -1                # fixed-iter contract (no target)
    assert np.all(np.isfinite(out.x))
    assert out.res_norms.shape[0] == 31   # 3 chunks of 10, head + deltas
    a = _csr(m)
    assert (np.linalg.norm(b - a @ out.x)
            < 1e-3 * np.linalg.norm(b))   # 30 PCG iters genuinely happened


# -- the deprecated SolveServer shim -----------------------------------------


def test_solve_server_shim_warns_and_stays_on_the_plan_surface():
    m = laplacian_2d(8)
    eng = AzulEngine(m, precond="jacobi", dtype=np.float64)
    spec = SolveSpec(method="pcg_tol", tol=TOL, max_iters=200)
    _reset_deprecation_warnings()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        srv = SolveServer(eng, spec=spec)
    assert any(issubclass(w.category, DeprecationWarning)
               and "SolveService" in str(w.message) for w in rec)
    b = _rhs(m.shape[0], 17)
    rid = srv.submit(b)
    out = srv.step()[rid]
    # bit-identical to executing the batch-1 plan directly: the shim adds
    # management, never math
    from dataclasses import replace
    plan = eng.plan(replace(srv._op.cspec, batch=1))
    x, norms = plan(b[None])
    assert np.array_equal(out.x, np.asarray(x)[0])
    assert out.status == "converged"


# -- stats: the legacy dict shape, now a write-through registry view ---------


def test_stats_is_the_exact_legacy_dict_shape():
    """``SolveService.stats`` became a write-through view over the obs
    registry; to every reader it must stay EXACTLY the legacy dict --
    same keys, same initial values, plain-dict equality -- and every bump
    must land in ``repro_serve_events_total`` under this service's
    label."""
    from repro import obs

    svc, m = _service(8)
    legacy = {
        "requests": 0, "batches": 0, "padded_rhs": 0, "plans": 0,
        "rejected": 0, "degraded_batches": 0, "deadline_batches": 0,
        "deadline_exceeded": 0, "straggler_chunks": [],
        "ticks": 0, "chunks": 0, "admitted": 0, "completed": 0,
        "rebuckets": 0, "padded_lanes": 0, "queue_peak": 0,
        "evictions": 0, "reloads": 0, "rejects": {},
    }
    assert dict(svc.stats) == legacy
    assert isinstance(svc.stats, dict)            # readers see a dict
    assert isinstance(svc.stats["rejects"], dict)

    rid = svc.submit(_rhs(m.shape[0], 23))
    out = svc.drain()[rid]
    assert out.status == "converged"
    assert svc.stats["requests"] == 1
    assert svc.stats["completed"] == 1
    assert svc.stats["ticks"] >= 1

    ev = obs.REGISTRY.get("repro_serve_events_total")
    svc_label = svc._obs_label
    for key in ("requests", "completed", "ticks", "chunks"):
        assert ev.value(service=svc_label, event=key) == svc.stats[key], key
    # structured rejection mirrors into repro_serve_rejects_total
    with pytest.raises(SolveRequestError):
        svc.submit(np.ones(3))                    # wrong length
    assert svc.stats["rejected"] == 1
    reason = next(iter(svc.stats["rejects"]))
    rj = obs.REGISTRY.get("repro_serve_rejects_total")
    assert rj.value(service=svc_label, reason=reason) == 1
    # gauges track residency and queue high-water
    assert (obs.REGISTRY.get("repro_serve_resident_bytes")
            .value(service=svc_label)) == svc.resident_bytes()
    assert (obs.REGISTRY.get("repro_serve_queue_peak")
            .value(service=svc_label)) == svc.stats["queue_peak"]
