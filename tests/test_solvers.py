"""Solver correctness & convergence vs numpy ground truth."""

import numpy as np
import scipy.sparse as sp

import jax.numpy as jnp

from repro.core.engine import AzulEngine
from repro.core.formats import ell_from_csr
from repro.core.precond import apply_ic0, ic0
from repro.core.solvers import cg, pcg_tol
from repro.core.spops import spmv_ell_padded
from repro.data.matrices import laplacian_2d, random_spd


def _spd(n=80, seed=0):
    m = random_spd(n, density=0.05, seed=seed)
    a = sp.csr_matrix((m.data, m.indices, m.indptr), shape=m.shape)
    return m, a


def test_cg_matches_numpy():
    m, a = _spd()
    rng = np.random.default_rng(0)
    x_true = rng.standard_normal(m.shape[0])
    b = a @ x_true
    e = ell_from_csr(m, dtype=np.float64)
    mv = lambda x: spmv_ell_padded(e.cols, e.vals, x)[: m.shape[0]]
    res = cg(mv, jnp.asarray(b), iters=150)
    assert np.allclose(np.asarray(res.x), x_true, atol=1e-6)
    assert res.res_norms[-1] < 1e-6 * np.linalg.norm(b)


def test_pcg_monotone_tail_and_jacobi_helps():
    m, a = _spd(100, 1)
    b = a @ np.ones(100)
    eng_j = AzulEngine(m, precond="jacobi", dtype=np.float64)
    eng_n = AzulEngine(m, precond="none", dtype=np.float64)
    _, nj = eng_j.solve(b, method="pcg", iters=60)
    _, nn = eng_n.solve(b, method="pcg", iters=60)
    assert nj[-1] <= nn[-1] * 10  # jacobi never catastrophically worse
    assert nj[-1] < 1e-6 * np.linalg.norm(b)


def test_pcg_tol_stops_early():
    m, a = _spd(60, 2)
    b = a @ np.ones(60)
    e = ell_from_csr(m, dtype=np.float64)
    mv = lambda x: spmv_ell_padded(e.cols, e.vals, x)[:60]
    res = pcg_tol(mv, jnp.asarray(b), psolve=lambda r: r, tol=1e-6, max_iters=500)
    assert int(res.iters) < 500
    assert res.res_norms[-1] <= 1e-6 * np.linalg.norm(b) * 1.01


def test_jacobi_converges_on_diag_dominant():
    m = laplacian_2d(12)
    a = sp.csr_matrix((m.data, m.indices, m.indptr), shape=m.shape)
    b = a @ np.ones(m.shape[0])
    eng = AzulEngine(m, precond="jacobi", dtype=np.float64)
    x, norms = eng.solve(b, method="jacobi", iters=400)
    assert norms[-1] < norms[0] * 1e-2


def test_ic0_factorization_and_apply():
    m = laplacian_2d(10)
    a = np.asarray(
        sp.csr_matrix((m.data, m.indices, m.indptr), shape=m.shape).todense()
    )
    f = ic0(m, dtype=np.float64)
    # L L^T should approximate A on A's sparsity pattern
    from repro.core.formats import ell_to_dense

    l = ell_to_dense(f.ell_l)
    llt = l @ l.T
    mask = a != 0
    assert np.allclose(llt[mask], a[mask], atol=1e-8)
    # apply = (L L^T)^-1 r
    r = np.random.default_rng(0).standard_normal(m.shape[0])
    z = np.asarray(apply_ic0(f, jnp.asarray(r)))
    z_ref = np.linalg.solve(llt, r)
    assert np.allclose(z, z_ref, atol=1e-8)


def test_ic0_preconditioned_pcg_beats_jacobi_iterations():
    m = laplacian_2d(16)
    a = sp.csr_matrix((m.data, m.indices, m.indptr), shape=m.shape)
    b = a @ np.ones(m.shape[0])
    bn = np.linalg.norm(b)
    it = {}
    for pc in ("jacobi", "block_ic0"):
        eng = AzulEngine(m, precond=pc, dtype=np.float64)
        _, norms = eng.solve(b, method="pcg", iters=120)
        rel = norms / bn
        it[pc] = int(np.argmax(rel < 1e-9)) if (rel < 1e-9).any() else 120
    assert it["block_ic0"] <= it["jacobi"]


def test_pipelined_cg_matches_pcg():
    m = laplacian_2d(14)
    a = sp.csr_matrix((m.data, m.indices, m.indptr), shape=m.shape)
    x_true = np.random.default_rng(3).standard_normal(m.shape[0])
    b = a @ x_true
    eng = AzulEngine(m, precond="jacobi", dtype=np.float64)
    x1, _ = eng.solve(b, method="pcg", iters=100)
    x2, _ = eng.solve(b, method="pcg_pipelined", iters=100)
    assert np.allclose(x1, x_true, atol=1e-8)
    assert np.allclose(x2, x_true, atol=1e-7)
