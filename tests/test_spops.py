"""Single-device sparse ops vs scipy ground truth."""

import numpy as np
import scipy.sparse as sp
from _hypothesis_compat import given, settings, strategies as st
from scipy.linalg import solve_triangular

import jax.numpy as jnp

from repro.core.formats import (bcsr_from_csr, csr_from_scipy, ell_from_csr,
                                hyb_from_csr, sell_from_csr)
from repro.core.levels import build_schedule
from repro.core.spops import (extract_diag_ell, spmv_bcsr, spmv_ell,
                              spmv_hyb_padded, spmv_sell_flat, sptrsv_ell,
                              sptrsv_ell_unrolled)


@given(st.integers(4, 80), st.floats(0.02, 0.4), st.integers(0, 10**6))
@settings(max_examples=25, deadline=None)
def test_spmv_ell_matches_scipy(n, density, seed):
    a = sp.random(n, n, density=density, random_state=seed, format="csr")
    a.setdiag(1.5)
    m = csr_from_scipy(a.tocsr())
    x = np.random.default_rng(seed).standard_normal(n)
    e = ell_from_csr(m, dtype=np.float64)
    y = np.asarray(spmv_ell(e, jnp.asarray(x)))
    assert np.allclose(y, a @ x, atol=1e-9)


@given(st.integers(4, 60), st.floats(0.05, 0.3), st.integers(0, 10**6),
       st.sampled_from([(2, 4), (8, 16)]))
@settings(max_examples=15, deadline=None)
def test_spmv_bcsr_matches_scipy(n, density, seed, blk):
    a = sp.random(n, n, density=density, random_state=seed, format="csr")
    a.setdiag(1.5)
    m = csr_from_scipy(a.tocsr())
    x = np.random.default_rng(seed).standard_normal(n)
    b = bcsr_from_csr(m, bm=blk[0], bn=blk[1], dtype=np.float64)
    y = np.asarray(spmv_bcsr(b, jnp.asarray(x)))
    assert np.allclose(y, a @ x, atol=1e-9)


@given(st.integers(2, 60), st.floats(0.05, 0.5), st.integers(0, 10**6))
@settings(max_examples=20, deadline=None)
def test_sptrsv_matches_scipy(n, density, seed):
    a = sp.random(n, n, density=density, random_state=seed, format="csr")
    l = (sp.tril(a, k=-1) + sp.eye(n) * 2.0).tocsr()
    m = csr_from_scipy(l)
    e = ell_from_csr(m, dtype=np.float64)
    sched = build_schedule(m)
    b = np.random.default_rng(seed).standard_normal(n)
    x = np.asarray(sptrsv_ell(e, sched, jnp.asarray(b)))
    ref = solve_triangular(np.asarray(l.todense()), b, lower=True)
    assert np.allclose(x, ref, atol=1e-8)


@given(st.integers(4, 60), st.floats(0.05, 0.4), st.integers(0, 10**6))
@settings(max_examples=15, deadline=None)
def test_spmv_sell_matches_scipy(n, density, seed):
    a = sp.random(n, n, density=density, random_state=seed, format="csr")
    a.setdiag(1.5)
    m = csr_from_scipy(a.tocsr())
    x = np.random.default_rng(seed).standard_normal(n)
    s = sell_from_csr(m, slice_height=8, row_pad=8, dtype=np.float64)
    x_pad = np.zeros(s.rows_padded)
    x_pad[:n] = x
    y = np.asarray(spmv_sell_flat(s, jnp.asarray(x_pad)))
    assert np.allclose(y[:n], a @ x, atol=1e-9)
    assert np.allclose(y[n:], 0.0)


@given(st.integers(4, 60), st.floats(0.05, 0.4), st.integers(0, 10**6))
@settings(max_examples=15, deadline=None)
def test_spmv_hyb_matches_scipy(n, density, seed):
    a = sp.random(n, n, density=density, random_state=seed, format="csr")
    a.setdiag(1.5)
    m = csr_from_scipy(a.tocsr())
    x = np.random.default_rng(seed).standard_normal(n)
    h = hyb_from_csr(m, row_pad=8, dtype=np.float64)
    x_pad = np.zeros(h.rows_padded)
    x_pad[:n] = x
    y = np.asarray(spmv_hyb_padded(h, jnp.asarray(x_pad)))
    assert np.allclose(y[:n], a @ x, atol=1e-9)


@given(st.integers(8, 60), st.floats(0.05, 0.4), st.integers(0, 10**6))
@settings(max_examples=10, deadline=None)
def test_sptrsv_scan_bitwise_matches_unrolled(n, density, seed):
    """The lax.scan wavefront (O(1) traced statements, sublinear compile in
    levels) must be BITWISE identical to the unrolled per-level Python loop
    it replaced -- same arithmetic, different program shape.  Both sides
    jit-compiled: eager dispatch fuses the level body differently and can
    drift an ulp."""
    import jax

    a = sp.random(n, n, density=density, random_state=seed, format="csr")
    l = (sp.tril(a, k=-1) + sp.eye(n) * 2.0).tocsr()
    m = csr_from_scipy(l)
    e = ell_from_csr(m, dtype=np.float64)
    sched = build_schedule(m)
    b = np.random.default_rng(seed).standard_normal(n)
    x_scan = np.asarray(jax.jit(
        lambda bb: sptrsv_ell(e, sched, bb))(jnp.asarray(b)))
    x_unrl = np.asarray(jax.jit(
        lambda bb: sptrsv_ell_unrolled(e, sched, bb))(jnp.asarray(b)))
    np.testing.assert_array_equal(x_scan, x_unrl)


def test_extract_diag():
    a = sp.diags([np.arange(1.0, 9.0)], [0]).tocsr() + sp.random(
        8, 8, density=0.2, random_state=0
    ).tocsr()
    a = sp.tril(a).tocsr()
    m = csr_from_scipy(a)
    e = ell_from_csr(m, dtype=np.float64)
    d = np.asarray(extract_diag_ell(e))
    assert np.allclose(d, a.diagonal())
