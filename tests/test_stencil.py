"""Matrix-free stencil operators: matvec vs the assembled CSR oracle, the
engine's stencil mode (same SolverDef plumbing as stored matrices), and the
forcing rules that keep stencils out of modes that need stored values."""

import numpy as np
import pytest
import scipy.sparse as sp

import jax.numpy as jnp

from repro.core.engine import AzulEngine
from repro.core.plan import SolveSpec
from repro.core.stencil import (lap2d_stencil, lap3d_stencil, stencil_diag,
                                stencil_matvec)
from repro.data.matrices import laplacian_2d, laplacian_3d


def _as_scipy(m):
    return sp.csr_matrix((m.data, m.indices, m.indptr), shape=m.shape)


@pytest.mark.parametrize("nx,ny", [(5, 7), (8, 8), (16, 4)])
def test_lap2d_matvec_matches_assembled(nx, ny):
    st = lap2d_stencil(nx, ny)
    a = _as_scipy(laplacian_2d(nx, ny))
    assert st.n == a.shape[0]
    x = np.random.default_rng(0).standard_normal(st.n)
    y = np.asarray(stencil_matvec(st, jnp.asarray(x)))
    np.testing.assert_allclose(y, a @ x, atol=1e-5)


@pytest.mark.parametrize("n", [3, 4, 6])
def test_lap3d_matvec_matches_assembled(n):
    st = lap3d_stencil(n)
    a = _as_scipy(laplacian_3d(n))
    x = np.random.default_rng(1).standard_normal(st.n)
    y = np.asarray(stencil_matvec(st, jnp.asarray(x)))
    np.testing.assert_allclose(y, a @ x, atol=1e-5)
    assert stencil_diag(st) == 6.0


def test_stencil_matvec_padded_and_batched():
    st = lap2d_stencil(6, 5)          # n = 30, pads to 32
    n, n_pad = st.n, 32
    rng = np.random.default_rng(2)
    x = rng.standard_normal((3, n_pad))
    x[:, n:] = 0.0
    y = np.asarray(stencil_matvec(st, jnp.asarray(x), n_pad))
    assert y.shape == (3, n_pad)
    a = _as_scipy(laplacian_2d(6, 5))
    np.testing.assert_allclose(y[:, :n], (a @ x[:, :n].T).T, atol=1e-5)
    np.testing.assert_allclose(y[:, n:], 0.0)


@pytest.mark.parametrize("batched", [False, True])
def test_engine_stencil_solve_matches_assembled(batched):
    st = lap2d_stencil(12, 9)
    m = laplacian_2d(12, 9)
    rng = np.random.default_rng(3)
    b = rng.standard_normal((2, st.n) if batched else (st.n,))
    e_st = AzulEngine(st, mesh=None, precond="jacobi", dtype=np.float64)
    e_ms = AzulEngine(m, mesh=None, precond="jacobi", dtype=np.float64)
    assert e_st.format_choice == "stencil"
    spec = SolveSpec(method="pcg_tol", tol=1e-9, iters=400,
                     batch=2 if batched else None)
    p_st = e_st.plan(spec)
    p_ms = e_ms.plan(spec)
    assert p_st.info["format"] == "stencil"
    x_st, _ = p_st(b)
    x_ms, _ = p_ms(b)
    np.testing.assert_allclose(x_st, x_ms, atol=1e-7)
    assert int(np.max(np.asarray(p_st.last_iters))) == \
        int(np.max(np.asarray(p_ms.last_iters)))


def test_engine_stencil_guard_and_spmv():
    st = lap3d_stencil(5)
    eng = AzulEngine(st, mesh=None, precond="none", dtype=np.float64)
    b = np.random.default_rng(4).standard_normal(st.n)
    p = eng.plan(SolveSpec(method="pcg_tol", tol=1e-8, iters=300, guard=True))
    x, _ = p(b)
    assert p.last_status_names == "converged"
    a = _as_scipy(laplacian_3d(5))
    np.testing.assert_allclose(np.asarray(eng.spmv(x)), a @ x.T if x.ndim == 2
                               else a @ x, atol=1e-6)


def test_engine_stencil_forcing_rules():
    st = lap2d_stencil(8)
    # modes that need stored matrix values are rejected up front
    with pytest.raises(ValueError):
        AzulEngine(st, mesh=None, precond="block_ic0")
    with pytest.raises(ValueError):
        AzulEngine(st, mesh=None, format="hyb")
    eng = AzulEngine(st, mesh=None, precond="jacobi", dtype=np.float64)
    with pytest.raises(ValueError):
        eng.plan(SolveSpec(method="pcg", iters=5, injectable=True))
    with pytest.raises(ValueError):
        eng.plan(SolveSpec(method="pcg", iters=5, format="ell"))
    with pytest.raises(ValueError):
        eng.vals_template()
    # and the converse: a stored-matrix engine cannot claim format=stencil
    m = laplacian_2d(8)
    with pytest.raises(ValueError):
        AzulEngine(m, mesh=None, format="stencil")
    eng_m = AzulEngine(m, mesh=None, dtype=np.float64)
    with pytest.raises(ValueError):
        eng_m.plan(SolveSpec(method="pcg", iters=5, format="stencil"))


@pytest.mark.slow
def test_engine_stencil_large_n_smoke():
    """The point of matrix-free: n = 262144 builds in O(n) memory (no
    assembled CSR, no ELL) and takes solver iterations immediately."""
    st = lap2d_stencil(512)
    eng = AzulEngine(st, mesh=None, precond="jacobi", dtype=np.float32)
    assert eng.ell is None
    assert eng.device_bytes() <= 32 * st.n      # vectors only, no matrix
    b = np.random.default_rng(6).standard_normal(st.n).astype(np.float32)
    x, norms = eng.solve(b, method="pcg", iters=8)
    assert np.isfinite(np.asarray(norms)).all()
    assert float(norms[-1]) < float(norms[0])
