"""Substrate behaviour: optimizers, checkpoints, restart/NaN-guard,
straggler detection, data determinism, serving."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore, save
from repro.data import TokenPipeline
from repro.ft import RestartManager, StepTimer
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.serve import SlotServer, generate
from repro.train import (adafactor, adamw, build_train_step,
                         init_train_state, warmup_cosine)

CFG = ModelConfig(n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
                  d_ff=64, vocab_size=64, param_dtype="float32",
                  compute_dtype="float32", remat=True)


@pytest.fixture(scope="module")
def setup():
    params = M.init_params(jax.random.PRNGKey(0), CFG)
    opt = adamw(warmup_cosine(3e-3, 5, 100))
    state = init_train_state(params, opt)
    step = jax.jit(build_train_step(CFG, opt, grad_accum=2))
    pipe = TokenPipeline(CFG.vocab_size, batch=8, seq_len=16, seed=0)
    return params, opt, state, step, pipe


def test_loss_decreases(setup):
    _, _, state, step, pipe = setup
    losses = []
    for i in range(25):
        state, m = step(state, pipe.batch_at(i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]


def test_adafactor_trains(setup):
    params, _, _, _, pipe = setup
    opt = adafactor(warmup_cosine(1e-2, 3, 50))
    state = init_train_state(params, opt)
    step = jax.jit(build_train_step(CFG, opt))
    l0 = l1 = None
    for i in range(15):
        state, m = step(state, pipe.batch_at(i))
        if i == 0:
            l0 = float(m["loss"])
    l1 = float(m["loss"])
    assert l1 < l0
    # factored state is smaller than AdamW's
    af = sum(x.size for x in jax.tree.leaves(state.opt_state))
    aw = 2 * sum(x.size for x in jax.tree.leaves(params))
    assert af < 0.2 * aw


def test_compressed_grads_still_train(setup):
    params, _, _, _, pipe = setup
    opt = adamw(warmup_cosine(3e-3, 5, 100))
    state = init_train_state(params, opt, compress=True)
    step = jax.jit(build_train_step(CFG, opt, compress_grads=True))
    losses = []
    for i in range(20):
        state, m = step(state, pipe.batch_at(i))
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all() and losses[-1] < losses[0]


def test_checkpoint_roundtrip_and_gc(setup):
    _, _, state, _, _ = setup
    with tempfile.TemporaryDirectory() as d:
        for s in (1, 2, 3, 4):
            save(state._replace(step=jnp.int32(s)), d, s, keep=2)
        assert latest_step(d) == 4
        assert len([x for x in os.listdir(d) if x.startswith("step_")]) == 2
        st, s = restore(state, d)
        assert s == 4
        for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(state)):
            if hasattr(a, "shape") and a.shape == getattr(b, "shape", None):
                pass  # structural restore verified by tree match


def test_corrupt_manifest_falls_back(setup):
    _, _, state, _, _ = setup
    with tempfile.TemporaryDirectory() as d:
        save(state, d, 1, keep=None)
        save(state, d, 2, keep=None)
        # corrupt the newest manifest
        with open(os.path.join(d, "step_00000002", "manifest.json"), "w") as f:
            f.write("{broken")
        assert latest_step(d) == 1


def test_restart_after_injected_failure(setup):
    _, _, state, step, pipe = setup
    with tempfile.TemporaryDirectory() as d:
        rm = RestartManager(d, save_every=4)
        with pytest.raises(RuntimeError):
            rm.run(state, step, pipe, total_steps=12, inject_failure_at=9)
        res = rm.run(state, step, pipe, total_steps=12)
        assert res.resumed_from == 8
        assert int(np.asarray(res.state.step)) == 12


def test_pipeline_deterministic():
    p1 = TokenPipeline(64, 4, 16, seed=7)
    p2 = TokenPipeline(64, 4, 16, seed=7)
    b1, b2 = p1.batch_at(123), p2.batch_at(123)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(p1.batch_at(1)["tokens"], p1.batch_at(2)["tokens"])


def test_straggler_detector():
    t = StepTimer()
    flags = []
    for i in range(30):
        dur = 1.0 + (4.0 if i == 20 else 0.0)
        flags.append(t.observe(i, dur).is_straggler)
    assert flags[20] and sum(flags) == 1


def test_generate_and_slot_server(setup):
    params, _, _, _, _ = setup
    prompts = jnp.asarray(
        np.random.default_rng(0).integers(1, CFG.vocab_size, (2, 8)), jnp.int32
    )
    out = generate(params, CFG, prompts, steps=5)
    assert out.shape == (2, 5)
    srv = SlotServer(params, CFG, batch_slots=2, max_len=32)
    r0 = srv.submit(np.asarray(prompts[0]), 4)
    r1 = srv.submit(np.asarray(prompts[1]), 6)
    done = {}
    for _ in range(10):
        done.update(srv.step())
        if len(done) == 2:
            break
    assert set(done) == {r0, r1}
    assert len(done[r0]) == 4 + 1 and len(done[r1]) == 6 + 1
