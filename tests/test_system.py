"""End-to-end system tests: the paper's workload through the public API,
mirroring §IV (functional verification on toy cases + benchmark matrices),
plus the examples as smoke-runnable entry points."""

import subprocess
import sys
import os

import numpy as np
import pytest
import scipy.sparse as sp

from repro.core.engine import AzulEngine
from repro.data.matrices import banded_spd, laplacian_2d, laplacian_3d, random_spd, suite


def _solve_and_verify(m, precond, iters=150, rtol=1e-6):
    a = sp.csr_matrix((m.data, m.indices, m.indptr), shape=m.shape)
    rng = np.random.default_rng(0)
    x_true = rng.standard_normal(m.shape[0])
    b = a @ x_true
    eng = AzulEngine(m, mesh=None, precond=precond, dtype=np.float64)
    x, norms = eng.solve(b, method="pcg", iters=iters)
    assert norms[-1] <= rtol * np.linalg.norm(b), f"residual {norms[-1]}"
    assert np.allclose(x, x_true, atol=1e-4)


@pytest.mark.parametrize("gen,arg", [
    (laplacian_2d, 24), (laplacian_3d, 8), (banded_spd, 400), (random_spd, 300),
])
def test_pcg_on_suite_families(gen, arg):
    _solve_and_verify(gen(arg), "jacobi", iters=300)


def test_pcg_block_ic0_on_poisson():
    _solve_and_verify(laplacian_2d(24), "block_ic0", iters=120)


def test_suite_loader():
    mats = suite("small")
    assert len(mats) >= 4
    for name, m in mats.items():
        assert m.shape[0] == m.shape[1] and m.nnz > 0


@pytest.mark.slow
@pytest.mark.parametrize("script", ["quickstart.py", "distributed_solve.py"])
def test_examples_run(script):
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    r = subprocess.run(
        [sys.executable, os.path.join("examples", script)],
        capture_output=True, text=True, cwd=root, timeout=560,
        env={**os.environ, "PYTHONPATH": "src"},
    )
    assert r.returncode == 0, r.stderr[-2000:]
